"""Jit wrappers for the client_solve kernel: padding + the FedNew hook.

``client_solve(A, b, damping)`` pads d up to the 128-lane tile (identity
diagonal + zero rhs on the pad, so padded coordinates solve to exactly 0 and
never feed back into the CG recurrences), calls the Pallas kernel, and strips
the pad. ``repro.core.fednew`` routes eq. 9 through here (via
``repro.kernels.dispatch``) when the config's solve backend resolves to the
Pallas path. ``interpret=None`` means "ask the dispatch layer": compiled on
TPU, interpreter elsewhere — never the interpreter silently on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.client_solve.client_solve import client_solve_cg

LANE = 128


def _pad_up(d: int) -> int:
    return -(-d // LANE) * LANE


@partial(jax.jit, static_argnames=("damping", "iters", "interpret"))
def client_solve(
    A: jax.Array, b: jax.Array, *, damping: float, iters: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        from repro.kernels import dispatch

        interpret = dispatch.default_interpret()
    n, d, _ = A.shape
    dp = _pad_up(d)
    if dp != d:
        pad = dp - d
        A = jnp.pad(A, ((0, 0), (0, pad), (0, pad)))
        # identity on the padded diagonal keeps the system SPD; with zero rhs
        # the padded solution coordinates are exactly zero.
        diag = jnp.arange(d, dp)
        A = A.at[:, diag, diag].set(1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)))
    x = client_solve_cg(A, b, damping=damping, iters=iters, interpret=interpret)
    return x[:, :d]


def client_solve_from_chol(chol: jax.Array, rhs: jax.Array) -> jax.Array:
    """Back-compat hook for the faithful Cholesky path (repro.core.fednew):
    reconstruct A = L L^T - damping I is wasteful, so this simply runs the
    triangular solves — the CG kernel is exposed via ``client_solve`` and is
    exercised by the fednew step when configs carry raw Hessians."""
    import jax.scipy.linalg as jsl

    return jax.vmap(lambda L, r: jsl.cho_solve((L, True), r))(chol, rhs)
