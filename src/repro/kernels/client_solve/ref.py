"""Direct-solve oracle for the client_solve kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def client_solve_ref(A, b, *, damping: float):
    """(n,d,d), (n,d) -> exact (A_i + damping I)^{-1} b_i via LU."""
    d = A.shape[-1]
    damped = A.astype(jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    damped = damped + damping * jnp.eye(d, dtype=damped.dtype)
    return jax.vmap(jnp.linalg.solve)(damped, b.astype(damped.dtype)).astype(b.dtype)
