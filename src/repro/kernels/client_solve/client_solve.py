"""Batched damped-SPD solve kernel for FedNew's client sub-problem (eq. 9).

Each FL client must apply (H_i + (alpha+rho) I)^{-1} to its ADMM right-hand
side every round. At paper scale (d ≤ 267) the whole damped Hessian tile fits
VMEM with room to spare, so the TPU-native design (DESIGN.md §3.4) keeps
A_i resident in VMEM and runs a fixed-iteration conjugate-gradient loop whose
matvec is a (d × d)·(d,) MXU contraction — no HBM traffic inside the loop,
one grid step per client.

The damping (alpha + rho) bounds the condition number, so a modest fixed
iteration count reaches float32 solve accuracy (tests sweep d, dtype, and
iteration count against ``ref.py``'s direct solve).

Shapes are padded to the 128-lane MXU tile by ``ops.py``; padding rows carry
an identity diagonal and zero rhs so they solve to exactly zero.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, x_ref, *, iters: int, damping: float):
    A = a_ref[0].astype(jnp.float32)  # (d, d) resident in VMEM
    b = b_ref[...].astype(jnp.float32)  # (1, d)

    def matvec(p):  # (1,d) @ (d,d) on the MXU; A is symmetric
        return jax.lax.dot_general(
            p, A, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) + damping * p

    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.sum(r * r)

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = jnp.sum(p * ap)
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r)
        beta = jnp.where(rs > 0, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = r + beta * p
        return x, r, p, rs_new

    x, r, p, rs = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    x_ref[...] = x.astype(x_ref.dtype)


def client_solve_cg(
    A: jax.Array,  # (n, d, d) — local Hessians, WITHOUT damping
    b: jax.Array,  # (n, d) — ADMM rhs g_i - lam_i + rho y
    *,
    damping: float,
    iters: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """(n, d) solutions of (A_i + damping·I) x = b_i, one grid step/client."""
    n, d, _ = A.shape
    kernel = functools.partial(_kernel, iters=iters, damping=damping)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), b.dtype),
        interpret=interpret,
    )(A, b)
