"""Backend-aware dispatch for the FedNew hot-path kernels.

The engine has exactly two byte-moving inner loops — the eq. 9 client solve
and the eqs. 25-30 stochastic quantizer — and each exists in two
implementations: a Pallas TPU kernel (``repro.kernels.<name>``) and a jnp
reference (``repro.core.quantization`` / ``client_solve/ref.py``). This
module owns the routing between them so no call site ever hardcodes
``interpret=True`` (the "silent interpreter" bug) or imports a kernel
module directly.

Backend names accepted from configs / ``engine.get_solver``:

  ``auto``       pick per platform: compiled Pallas on TPU, the jnp
                 reference elsewhere (the interpreter is a correctness tool,
                 not a fast path — never selected silently).
  ``pallas``     force the kernel: compiled on TPU, ``interpret`` mode on
                 CPU/GPU (explicitly requested, so interpretation is fine).
  ``reference``  force the jnp path.

``resolve_backend`` maps those onto the *resolved* execution modes
``pallas`` / ``pallas-interpret`` / ``reference``; the resolved name is what
tests assert against. The ``REPRO_KERNEL_BACKEND`` environment variable
overrides how ``auto`` resolves (CI uses it to run the interpret leg without
touching configs).

The kernel registry itself lives here (populated by
``repro.kernels.__init__``); entries are module-path strings resolved
lazily, so importing this module never drags in a kernel that the selected
backend will not use.
"""

from __future__ import annotations

import importlib
import os
from typing import Callable, Dict, Optional

import jax

BACKENDS = ("auto", "pallas", "reference")
RESOLVED_BACKENDS = ("pallas", "pallas-interpret", "reference")

ENV_BACKEND = "REPRO_KERNEL_BACKEND"


def platform() -> str:
    """The XLA platform kernels would compile for ('tpu', 'cpu', 'gpu')."""
    return jax.default_backend()


def validate_backend(backend: str) -> str:
    if backend not in BACKENDS and backend not in RESOLVED_BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of "
            f"{BACKENDS} (or resolved {RESOLVED_BACKENDS})"
        )
    return backend


def resolve_backend(backend: str = "auto", plat: Optional[str] = None) -> str:
    """Map a config-level backend name to a resolved execution mode.

    'auto'   -> 'pallas' on TPU, else 'reference' (env override honored)
    'pallas' -> 'pallas' on TPU, else 'pallas-interpret'
    already-resolved names pass through unchanged.
    """
    validate_backend(backend)
    plat = platform() if plat is None else plat
    if backend == "auto":
        env = os.environ.get(ENV_BACKEND)
        if env:
            return resolve_backend(validate_backend(env), plat)
        return "pallas" if plat == "tpu" else "reference"
    if backend == "pallas":
        return "pallas" if plat == "tpu" else "pallas-interpret"
    return backend  # 'reference' / 'pallas-interpret'


def use_pallas(resolved: str) -> bool:
    """True when the resolved mode executes the Pallas kernel."""
    return resolved in ("pallas", "pallas-interpret")


def interpret_flag(resolved: str) -> bool:
    """The ``interpret=`` argument the kernel wrapper should receive."""
    return resolved == "pallas-interpret"


def default_interpret() -> bool:
    """Backend-aware default for kernel wrappers called without an explicit
    ``interpret`` flag: compile on TPU, interpret elsewhere. This replaces
    the old hardcoded ``interpret=True`` defaults that sent TPU users
    through the interpreter silently."""
    return platform() != "tpu"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# name -> {resolved-flavor: "module.path:attr"}; flavors are "pallas"
# (kernel wrapper taking an ``interpret`` kwarg) and "reference" (pure jnp).
_REGISTRY: Dict[str, Dict[str, str]] = {}


def register_kernel(name: str, *, pallas: str, reference: str) -> None:
    """Register a dispatchable kernel (idempotent; later wins)."""
    _REGISTRY[name] = {"pallas": pallas, "reference": reference}


def registered_kernels() -> tuple:
    return tuple(sorted(_REGISTRY))


def _load(path: str) -> Callable:
    mod, _, attr = path.partition(":")
    return getattr(importlib.import_module(mod), attr)


def resolve_impl(name: str, backend: str = "auto") -> tuple:
    """Resolve ``backend`` and return ``(callable, resolved_flavor)`` from
    the registry. Pallas flavors degrade to the reference if the kernel
    fails to import (the 'jnp reference as last resort' leg) — the returned
    flavor says which implementation the caller actually got, so call sites
    know whether to pass kernel-only kwargs like ``interpret``."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; have {registered_kernels()}")
    entry = _REGISTRY[name]
    resolved = resolve_backend(backend)
    if use_pallas(resolved):
        try:
            return _load(entry["pallas"]), resolved
        except ImportError:
            resolved = "reference"
    return _load(entry["reference"]), resolved


def get_impl(name: str, backend: str = "auto") -> Callable:
    """Resolve ``backend`` and return the implementing callable."""
    return resolve_impl(name, backend)[0]


# ---------------------------------------------------------------------------
# dispatched ops — what fednew/fednew_hf/engine actually call
# ---------------------------------------------------------------------------


def quantize(key, y, y_hat_prev, bits: int, *, backend: str = "auto"):
    """Eq. 25-30 for one client vector; returns a QuantResult. Bit-exact
    across backends for float32 inputs (same key -> same levels)."""
    fn, resolved = resolve_impl("stoch_quant.quantize", backend)
    if use_pallas(resolved):
        return fn(key, y, y_hat_prev, bits, interpret=interpret_flag(resolved))
    return fn(key, y, y_hat_prev, bits)


def quantize_with_keys(keys, y, y_hat_prev, bits: int, *, backend: str = "auto"):
    """Batched eq. 25-30 over a leading client axis with caller-supplied
    per-client keys — the engine's Q-FedNew hot loop, reached through the
    ``repro.comm`` stoch_quant codec (which keeps the integer levels as the
    wire payload and reconstructs ŷ itself so client and server agree bit
    for bit). The Pallas route runs one 2-D ``(clients, blocks)`` grid over
    the whole shard-local batch."""
    fn, resolved = resolve_impl("stoch_quant", backend)
    if use_pallas(resolved):
        return fn(keys, y, y_hat_prev, bits, interpret=interpret_flag(resolved))
    return fn(keys, y, y_hat_prev, bits)


def quantize_batch(key, y, y_hat_prev, bits: int, *, backend: str = "auto"):
    """Batched eq. 25-30, one PRNG split per client (leaf-wise fednew_hf
    route). Key-splitting matches ``quantization.quantize_batch`` exactly."""
    keys = jax.random.split(key, y.shape[0])
    return quantize_with_keys(keys, y, y_hat_prev, bits, backend=backend)


def client_solve(A, b, *, damping: float, iters: int = 32, backend: str = "auto"):
    """Eq. 9: batched (A_i + damping I)^{-1} b_i. The Pallas route is the
    in-VMEM CG kernel; the reference is the direct dense solve."""
    fn, resolved = resolve_impl("client_solve", backend)
    if use_pallas(resolved):
        return fn(A, b, damping=damping, iters=iters,
                  interpret=interpret_flag(resolved))
    return fn(A, b, damping=damping)
