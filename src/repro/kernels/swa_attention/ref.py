"""Pure-jnp oracle for the sliding-window attention kernel.

Deliberately naive: materializes the full (S, S) mask. Only run at test
sizes; the kernel and ``repro.models.attention`` are the production paths.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def swa_attention_ref(q, k, v, *, window: int, groups: int = 1, cap=None):
    """q (BH, S, dh); k/v (BHkv, S, dh); row r of q attends kv row r//groups."""
    BH, S, dh = q.shape
    kx = jnp.repeat(k, groups, axis=0)
    vx = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s * (dh ** -0.5)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    pos = jnp.arange(S)
    valid = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    s = jnp.where(valid[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vx.astype(jnp.float32)).astype(q.dtype)
