"""Jit-ready wrapper: model-layout adapter for the SWA Pallas kernel.

``swa_attention(q, k, v, window, ...)`` takes the model's (B, S, H, Dh) /
(B, S, Hkv, Dh) layout, flattens heads into the kernel's row-major grid,
dispatches to the Pallas kernel (interpret=True on CPU so tests exercise the
real kernel body), and restores the layout. This is what
``repro.models.attention`` calls when ``cfg.use_pallas`` is set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.swa_attention import swa_attention_fwd


@partial(jax.jit, static_argnames=("window", "q_blk", "cap", "interpret"))
def swa_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,
    *,
    window: int,
    q_blk: int = 128,
    cap: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    # rows G-major within each kv head: q row b*H + h_kv*G + g
    q2 = q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    k2 = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh)
    v2 = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh)
    out = swa_attention_fwd(
        q2, k2, v2, window=window, groups=G, q_blk=min(q_blk, S), cap=cap,
        interpret=interpret,
    )
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)
