"""Sliding-window flash-attention forward kernel (TPU Pallas).

The hot loop of the gemma3/gemma2/mixtral/recurrentgemma local layers: causal
attention restricted to the last ``window`` keys. The kernel tiles the query
axis into MXU-aligned blocks held in VMEM and walks only the KV blocks that
can intersect the window band — O(S · window) work and O(block) VMEM, versus
O(S²) for naive masking.

Grid: (B · Hkv · G, nq, nwin) — the innermost axis walks the band's KV blocks
with the online-softmax (m, l, acc) carried in VMEM scratch across grid
steps (TPU grids are sequential-minor, the canonical flash pattern).
Out-of-range band blocks are index-clamped to 0 and neutralized by the
position mask (clamped ≠ intended ⇒ every position fails the window test).

Numerics match ``ref.py`` (and ``repro.models.attention``): f32 scores and
accumulation, optional logit softcap, outputs cast to the query dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window: int, q_blk: int, nwin: int, cap, scale: float):
    iq = pl.program_id(1)
    j = pl.program_id(2)

    intended = iq - (nwin - 1) + j  # kv block index the band wants
    q = q_ref[0].astype(jnp.float32)  # (q_blk, dh)
    k = k_ref[0].astype(jnp.float32)  # (q_blk, dh) — kv tiled at q_blk
    v = v_ref[0].astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (q_blk, q_blk)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    qpos = iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 0)
    kpos = intended * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 1)
    valid = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nwin - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def swa_attention_fwd(
    q: jax.Array,  # (BH, S, dh) — B*Hkv*G rows, G-major within a kv head
    k: jax.Array,  # (BHkv, S, dh)
    v: jax.Array,
    *,
    window: int,
    groups: int = 1,  # G = H // Hkv; q row r reads kv row r // G
    q_blk: int = 128,
    cap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    BH, S, dh = q.shape
    assert S % q_blk == 0, (S, q_blk)
    nq = S // q_blk
    nwin = -(-window // q_blk) + 1  # ceil + the diagonal block
    scale = dh ** -0.5

    def q_map(b, iq, j):
        return (b, iq, 0)

    def kv_map(b, iq, j):
        blk = iq - (nwin - 1) + j
        return (b // groups, jnp.maximum(blk, 0), 0)

    kernel = functools.partial(
        _kernel, window=window, q_blk=q_blk, nwin=nwin, cap=cap, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nwin),
        in_specs=[
            pl.BlockSpec((1, q_blk, dh), q_map),
            pl.BlockSpec((1, q_blk, dh), kv_map),
            pl.BlockSpec((1, q_blk, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, q_blk, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
