from repro.kernels.swa_attention.ops import swa_attention
from repro.kernels.swa_attention.ref import swa_attention_ref
from repro.kernels.swa_attention.swa_attention import swa_attention_fwd
