# Pallas TPU kernels for the compute hot-spots this system optimizes
# (validated under interpret=True on CPU against each ref.py oracle):
#   swa_attention — flash sliding-window attention (gemma/mixtral local layers)
#   client_solve  — in-VMEM CG for FedNew's eq. 9 damped SPD solve
#   stoch_quant   — Q-FedNew stochastic quantizer (eqs. 25-30)
#   slstm_scan    — fused sLSTM recurrence (VMEM-resident state; §Perf pair C)
