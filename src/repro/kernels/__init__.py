"""Pallas TPU kernels for the compute hot-spots this system optimizes
(validated under interpret=True on CPU against each ref.py oracle):

  swa_attention — flash sliding-window attention (gemma/mixtral local layers)
  client_solve  — in-VMEM CG for FedNew's eq. 9 damped SPD solve
  stoch_quant   — Q-FedNew stochastic quantizer (eqs. 25-30), 2-D
                  (clients, blocks) grid with in-kernel tail masking
  slstm_scan    — fused sLSTM recurrence (VMEM-resident state; §Perf pair C)

The two FedNew hot loops (client_solve, stoch_quant) are registered with
the backend-aware dispatch layer (``repro.kernels.dispatch``) and reached
by the engine through it — call sites select ``auto``/``pallas``/
``reference`` instead of importing kernel modules or passing ``interpret=``
by hand. Entries are lazy module-path strings so importing this package
stays cheap.
"""

from repro.kernels import dispatch
from repro.kernels.dispatch import (  # noqa: F401  (public re-exports)
    get_impl,
    register_kernel,
    registered_kernels,
    resolve_backend,
)

dispatch.register_kernel(
    "client_solve",
    pallas="repro.kernels.client_solve.ops:client_solve",
    reference="repro.kernels.client_solve.ref:client_solve_ref",
)
# the engine's batched Q-FedNew hot loop ...
dispatch.register_kernel(
    "stoch_quant",
    pallas="repro.kernels.stoch_quant.ops:quantize_with_keys",
    reference="repro.core.quantization:quantize_with_keys",
)
# ... and the single-vector form (fednew_hf's shard_map one-client route)
dispatch.register_kernel(
    "stoch_quant.quantize",
    pallas="repro.kernels.stoch_quant.ops:quantize",
    reference="repro.core.quantization:quantize",
)
# LM fine-tuning hot spots: registered so the dispatch layer (and its
# interpret-mode CI sweep) covers every kernel package, not just the two
# FedNew loops — repro.analysis' kernel-pairing rule enforces this.
dispatch.register_kernel(
    "swa_attention",
    pallas="repro.kernels.swa_attention.ops:swa_attention",
    reference="repro.kernels.swa_attention.ref:swa_attention_ref",
)
dispatch.register_kernel(
    "slstm_scan",
    pallas="repro.kernels.slstm_scan.ops:slstm_scan",
    reference="repro.kernels.slstm_scan.ref:slstm_scan_ref",
)
