"""Stochastic quantizer kernel for Q-FedNew (paper eqs. 25-30).

Elementwise map over a *batch* of client direction vectors: given the
previous quantized vectors, per-client scalar ranges R_i (computed by a
cheap jnp row-max outside — one reduction; the elementwise pass is the
byte-moving hot loop), and pre-drawn uniforms, emit the integer levels and
the dequantized vectors.

Grid: 2-D ``(clients, blocks)`` over ``(1, block)`` tiles of the
``(n, N)`` batch — the shape the sharded engine hands each device inside
its ``shard_map`` region (``(n_clients/n_devices, d)``). Every tile loads
(y, ŷ_prev, u) into VMEM together with its client's R_i, computes

    c  = (y - ŷ + R) / Δ,   Δ = 2R / (2^bits - 1)
    q  = floor(c) + [u < frac(c)]          (unbiased, eqs. 26-28)
    ŷ' = ŷ + Δ·q - R                        (eq. 30)

entirely in registers/VMEM, and writes (q, ŷ') back. The trailing tile of a
row whose N is not a multiple of ``block`` is masked *in-kernel* (column
iota vs the true N), so callers never pad: out-of-range lanes produce
q = 0, ŷ' = ŷ_prev deterministically and Pallas drops the out-of-bounds
writes. The uniforms are taken as an input (rather than seeding in-kernel)
so the kernel is bit-exact against ``ref.py`` under any PRNG.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, prev_ref, u_ref, r_ref, q_ref, out_ref, *, bits: int,
            n_cols: int, block: int):
    j = pl.program_id(1)
    y = y_ref[...].astype(jnp.float32)  # (1, block)
    prev = prev_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    R = r_ref[0, 0]
    n_levels = float((1 << bits) - 1)
    delta = 2.0 * R / n_levels
    safe_delta = jnp.where(delta > 0, delta, 1.0)
    c = (y - prev + R) / safe_delta
    lo = jnp.floor(c)
    q = lo + (u < (c - lo)).astype(jnp.float32)
    q = jnp.clip(q, 0.0, n_levels)
    # In-kernel tail mask: lanes past the true row length carry whatever
    # Pallas padded in (garbage/NaN); force them to a defined (0, ŷ_prev)
    # before the store so interpret and compiled modes agree exactly.
    col = j * block + jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
    valid = col < n_cols
    q = jnp.where(valid, q, 0.0)
    y_hat = jnp.where(valid, prev + delta * q - R, prev)
    q_ref[...] = q.astype(q_ref.dtype)
    out_ref[...] = y_hat.astype(out_ref.dtype)


def stoch_quant(
    y: jax.Array,  # (n, N) batched directions, or (N,) single vector
    y_hat_prev: jax.Array,  # same shape as y
    u: jax.Array,  # same shape as y, uniforms in [0, 1)
    R: jax.Array,  # (n,) per-client ranges max|y_i - ŷ_i| (or scalar for 1-D)
    *,
    bits: int,
    block: int = 1024,
    interpret: bool = False,
):
    """Returns (levels int32, y_hat) with y's shape. N need not divide
    ``block`` — the trailing tile is masked in-kernel."""
    squeeze = y.ndim == 1
    if squeeze:
        y, y_hat_prev, u = y[None], y_hat_prev[None], u[None]
    n, N = y.shape
    R2 = jnp.broadcast_to(jnp.asarray(R, jnp.float32).reshape(-1, 1), (n, 1))
    grid = (n, -(-N // block))
    kernel = functools.partial(_kernel, bits=bits, n_cols=N, block=block)
    row_tile = pl.BlockSpec((1, block), lambda i, j: (i, j))
    q, y_hat = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row_tile,
            row_tile,
            row_tile,
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[row_tile, row_tile],
        out_shape=[
            jax.ShapeDtypeStruct((n, N), jnp.int32),
            jax.ShapeDtypeStruct((n, N), y.dtype),
        ],
        interpret=interpret,
    )(y, y_hat_prev, u, R2)
    if squeeze:
        return q[0], y_hat[0]
    return q, y_hat
