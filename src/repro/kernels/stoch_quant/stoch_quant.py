"""Stochastic quantizer kernel for Q-FedNew (paper eqs. 25-30).

Elementwise map over the client's direction vector: given the previous
quantized vector, the scalar range R (computed by a cheap jnp max outside —
it is one reduction; the elementwise pass is the byte-moving hot loop), and
pre-drawn uniforms, emit the integer levels and the dequantized vector.

Grid: 1-D over 128·8-aligned blocks of the flattened vector; every block
loads (y, ŷ_prev, u) tiles into VMEM, computes

    c  = (y - ŷ + R) / Δ,   Δ = 2R / (2^bits - 1)
    q  = floor(c) + [u < frac(c)]          (unbiased, eq. 26-28)
    ŷ' = ŷ + Δ·q - R                        (eq. 30)

entirely in registers/VMEM, and writes (q, ŷ') back. The uniforms are taken
as an input (rather than seeding in-kernel) so the kernel is bit-exact
against ``ref.py`` under any PRNG.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, prev_ref, u_ref, r_ref, q_ref, out_ref, *, bits: int):
    y = y_ref[...].astype(jnp.float32)
    prev = prev_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    R = r_ref[0, 0]
    n_levels = float((1 << bits) - 1)
    delta = 2.0 * R / n_levels
    safe_delta = jnp.where(delta > 0, delta, 1.0)
    c = (y - prev + R) / safe_delta
    lo = jnp.floor(c)
    q = lo + (u < (c - lo)).astype(jnp.float32)
    q = jnp.clip(q, 0.0, n_levels)
    q_ref[...] = q.astype(q_ref.dtype)
    out_ref[...] = (prev + delta * q - R).astype(out_ref.dtype)


def stoch_quant(
    y: jax.Array,  # (N,) flattened direction
    y_hat_prev: jax.Array,  # (N,)
    u: jax.Array,  # (N,) uniforms in [0, 1)
    R: jax.Array,  # () or (1,) scalar range max|y - y_hat_prev|
    *,
    bits: int,
    block: int = 1024,
    interpret: bool = False,
):
    """Returns (levels int32 (N,), y_hat (N,))."""
    (N,) = y.shape
    assert N % block == 0, (N, block)
    grid = (N // block,)
    R2 = jnp.reshape(R.astype(jnp.float32), (1, 1))
    kernel = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), y.dtype),
        ],
        interpret=interpret,
    )(y, y_hat_prev, u, R2)
