from repro.kernels.stoch_quant.ops import quantize, quantize_batch, quantize_with_keys
from repro.kernels.stoch_quant.ref import stoch_quant_ref
from repro.kernels.stoch_quant.stoch_quant import stoch_quant
