"""Jit wrapper: PRNG handling, padding, and the (levels, ŷ, Δ, payload)
result tuple matching ``repro.core.quantization.QuantResult``."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import R_BITS, QuantResult
from repro.kernels.stoch_quant.stoch_quant import stoch_quant

BLOCK = 1024


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize(key, y: jax.Array, y_hat_prev: jax.Array, bits: int,
             *, interpret: bool = True) -> QuantResult:
    """Kernel-backed drop-in for ``quantization.quantize`` (1-D input)."""
    (N,) = y.shape
    Np = -(-N // BLOCK) * BLOCK
    u = jax.random.uniform(key, (Np,), jnp.float32)
    R = jnp.max(jnp.abs(y - y_hat_prev))
    yp = jnp.pad(y, (0, Np - N))
    pp = jnp.pad(y_hat_prev, (0, Np - N))
    q, y_hat = stoch_quant(yp, pp, u, R, bits=bits, interpret=interpret)
    n_levels = (1 << bits) - 1
    delta = 2.0 * R / n_levels
    payload = jnp.asarray(bits * N + R_BITS, jnp.int32)
    return QuantResult(
        y_hat=y_hat[:N], levels=q[:N], delta=delta, payload_bits=payload
    )
