"""Jit wrappers for the stoch_quant kernel: PRNG handling and the
(levels, ŷ, Δ, payload) result tuple matching
``repro.core.quantization.QuantResult``.

Bit-exactness contract (float32): the uniforms are drawn exactly as the
reference draws them — ``N`` samples in ``y.dtype`` from the same key (the
old wrapper drew ``Np`` padded float32 samples, silently diverging from the
reference under the same key) — and the 2-D kernel masks row tails
in-kernel, so there is no host-side pad/copy at all. Same key therefore
produces the same integer levels on either path (the levels ARE the wire
payload); ``tests/test_dispatch.py`` pins this.

The dequantized vector these wrappers return is reconstructed from the
levels with the reference's exact expression (eq. 30) rather than taken
from the kernel's fused in-kernel dequant: XLA is free to contract
mul+add chains differently across separately-compiled programs (FMA,
reciprocal folding), so the in-kernel ŷ can drift a few ulps from the
reference while the levels stay identical. Reconstructing outside keeps
whole Q-FedNew trajectories bit-identical across backends; callers that
want the single-pass fused dequant (e.g. the kernel benchmark) use
``stoch_quant`` directly.

``interpret`` defaults to ``None`` = "ask the dispatch layer": compiled on
TPU, interpreter elsewhere. The old hardcoded ``interpret=True`` default
sent TPU users through the interpreter silently.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantResult, payload_bits, payload_bits_array
from repro.kernels.stoch_quant.stoch_quant import stoch_quant

BLOCK = 1024


def _resolve_interpret(interpret):
    if interpret is None:
        from repro.kernels import dispatch

        return dispatch.default_interpret()
    return interpret


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize(key, y: jax.Array, y_hat_prev: jax.Array, bits: int,
             *, interpret: bool | None = None) -> QuantResult:
    """Kernel-backed drop-in for ``quantization.quantize`` (1-D input)."""
    interpret = _resolve_interpret(interpret)
    (N,) = y.shape
    # Identical draw to the reference: N uniforms, y's dtype, same key.
    u = jax.random.uniform(key, (N,), y.dtype)
    diff = y - y_hat_prev
    R = jnp.max(jnp.abs(diff))
    q, _ = stoch_quant(y, y_hat_prev, u, R, bits=bits,
                       block=BLOCK, interpret=interpret)
    n_levels = (1 << bits) - 1
    delta = 2.0 * R / n_levels
    # eq. 30 with the reference's expression (see module docstring)
    y_hat = y_hat_prev + delta * q.astype(y.dtype) - R
    payload = payload_bits_array(payload_bits(bits, N))
    return QuantResult(y_hat=y_hat, levels=q, delta=delta, payload_bits=payload)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_with_keys(keys, y: jax.Array, y_hat_prev: jax.Array, bits: int,
                       *, interpret: bool | None = None) -> QuantResult:
    """Kernel-backed drop-in for ``quantization.quantize_with_keys``:
    a ``(clients, d)`` batch with caller-supplied per-client keys, quantized
    by ONE 2-D ``(clients, blocks)`` Pallas grid (the sharded engine feeds
    this its per-device ``(n_clients/n_devices, d)`` tile directly)."""
    interpret = _resolve_interpret(interpret)
    n, N = y.shape
    # Per-client draws identical to the reference's vmapped quantize.
    u = jax.vmap(lambda k: jax.random.uniform(k, (N,), y.dtype))(keys)
    diff = y - y_hat_prev
    R = jnp.max(jnp.abs(diff), axis=1)  # (n,) per-client ranges
    q, _ = stoch_quant(y, y_hat_prev, u, R, bits=bits,
                       block=BLOCK, interpret=interpret)
    n_levels = (1 << bits) - 1
    delta = 2.0 * R / n_levels
    y_hat = y_hat_prev + delta[:, None] * q.astype(y.dtype) - R[:, None]
    payload = jnp.broadcast_to(
        payload_bits_array(payload_bits(bits, N)), (n,)
    )
    return QuantResult(y_hat=y_hat, levels=q, delta=delta, payload_bits=payload)


def quantize_batch(key, y: jax.Array, y_hat_prev: jax.Array, bits: int,
                   *, interpret: bool | None = None) -> QuantResult:
    """Kernel-backed drop-in for ``quantization.quantize_batch`` (same
    key-splitting, so randomness matches the reference per client)."""
    keys = jax.random.split(key, y.shape[0])
    return quantize_with_keys(keys, y, y_hat_prev, bits, interpret=interpret)
