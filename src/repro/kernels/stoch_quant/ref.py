"""jnp oracle for the stoch_quant kernel: the paper's eqs. 25-30 given
pre-drawn uniforms (bit-exact contract with the kernel). Accepts a single
``(N,)`` vector with scalar R or a batched ``(n, N)`` block with per-row
``(n,)`` ranges, mirroring the kernel's 2-D grid."""

from __future__ import annotations

import jax.numpy as jnp


def stoch_quant_ref(y, y_hat_prev, u, R, *, bits: int):
    yf = y.astype(jnp.float32)
    pf = y_hat_prev.astype(jnp.float32)
    n_levels = float((1 << bits) - 1)
    R = jnp.asarray(R, jnp.float32)
    R = R.reshape(-1, 1) if y.ndim == 2 else R.reshape(())
    delta = 2.0 * R / n_levels
    safe_delta = jnp.where(delta > 0, delta, 1.0)
    c = (yf - pf + R) / safe_delta
    lo = jnp.floor(c)
    q = lo + (u.astype(jnp.float32) < (c - lo)).astype(jnp.float32)
    q = jnp.clip(q, 0.0, n_levels)
    return q.astype(jnp.int32), (pf + delta * q - R).astype(y.dtype)
